"""Asymmetric Higher-order Linear Attention (AHLA) — paper Section 6.

    AHLA(Q,K,V) = ((A A) . L) V,   A = L . (Q K^T)

Since A is lower-triangular, (A A) is already causal; the operator factors
as two first-order passes:  o = A (A V)  — i.e. ``LinAttn(q, k, LinAttn(q,
k, v))``.  We provide:

* ``ahla_naive``     — materialized oracle.
* ``ahla_serial``    — Algorithm 2 verbatim (streaming state P, m, E, n).
* ``ahla_scan``      — token-level associative scan with the Eq. (6.2)
                       monoid on (R, P, m, E, n) (+ decay-corrected variant).
* ``ahla_chunkwise`` — two chunked linear-attention passes (TPU-adapted).

Decay erratum (mirrors the HLA2 one, DESIGN.md §7): the paper's decayed
concatenation uses the *decayed* segment moment ``R_B`` in the cross terms,
which breaks associativity.  The consistent operator carries the
*undecayed* cross moment ``R~_B = sum_{i in B} k_i q_i^T`` (composing
purely additively) with cross term ``rho_B * R~_B P_A``.  At gamma=1 the
two coincide (Eq. 6.2).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .hla2 import _compute_dtype, _decay_matrices, _gamma_arr
from .linear_attn import LinAttnState, linattn_chunkwise


class AHLAState(NamedTuple):
    """Streaming state (Fig. 2(A)) + undecayed cross moment for scans."""

    R: jax.Array  # (..., d, d)  sum k q^T (undecayed; scan-only, Section 6.2)
    P: jax.Array  # (..., d, dv)
    m: jax.Array  # (..., d)
    E: jax.Array  # (..., d, dv)
    n: jax.Array  # (..., d)


def ahla_init_state(batch_shape, d, dv, dtype=jnp.float32) -> AHLAState:
    z = functools.partial(jnp.zeros, dtype=dtype)
    return AHLAState(
        R=z(batch_shape + (d, d)),
        P=z(batch_shape + (d, dv)),
        m=z(batch_shape + (d,)),
        E=z(batch_shape + (d, dv)),
        n=z(batch_shape + (d,)),
    )


def ahla_step(
    state: AHLAState, q_t, k_t, v_t, gamma=None,
    *, normalize: bool = False, eps: float = 1e-6,
):
    """Algorithm 2, one token.  E uses the *inclusive* P_t (Theorem 6.1)."""
    dtype = state.P.dtype
    q_t, k_t, v_t = q_t.astype(dtype), k_t.astype(dtype), v_t.astype(dtype)
    g = _gamma_arr(gamma, q_t.shape[:-1], dtype)
    gv, gm = g[..., None], g[..., None, None]

    P = gm * state.P + k_t[..., :, None] * v_t[..., None, :]
    m = gv * state.m + k_t
    r = jnp.einsum("...d,...de->...e", q_t, P)  # q_t^T P_t
    s = jnp.einsum("...d,...d->...", q_t, m)  # q_t^T m_t
    E = gm * state.E + k_t[..., :, None] * r[..., None, :]
    nn = gv * state.n + s[..., None] * k_t
    R = state.R + k_t[..., :, None] * q_t[..., None, :]  # undecayed (scan aux)
    o = jnp.einsum("...d,...de->...e", q_t, E)
    if normalize:
        den = jnp.einsum("...d,...d->...", q_t, nn)
        o = o / (den[..., None] + eps)
    return AHLAState(R, P, m, E, nn), o


def ahla_serial(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6,
    state: Optional[AHLAState] = None,
):
    batch_shape = q.shape[:-2]
    d, dv = q.shape[-1], v.shape[-1]
    if state is None:
        state = ahla_init_state(batch_shape, d, dv, _compute_dtype(q))

    def body(st, qkv):
        st, o = ahla_step(st, *qkv, gamma, normalize=normalize, eps=eps)
        return st, o

    qs, ks, vs = (jnp.moveaxis(x, -2, 0) for x in (q, k, v))
    state, os_ = jax.lax.scan(body, state, (qs, ks, vs))
    return jnp.moveaxis(os_, 0, -2).astype(v.dtype), state


def ahla_naive(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6
):
    """Oracle: o = A_g (A_g V) with A_g = (QK^T) . L_gamma (Eq. 6.1)."""
    dtype = _compute_dtype(q)
    q32, k32, v32 = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    n = q.shape[-2]
    g = _gamma_arr(gamma, q.shape[:-2], dtype)
    Lg, _ = _decay_matrices(n, g, dtype)
    A = jnp.einsum("...td,...jd->...tj", q32, k32) * Lg
    AA = jnp.einsum("...ti,...ij->...tj", A, A)
    num = jnp.einsum("...tj,...je->...te", AA, v32)
    if normalize:
        num = num / (jnp.sum(AA, -1)[..., None] + eps)
    return num.astype(v.dtype)


# -------------------- token-level associative scan (Eq. 6.2) ---------------


class AHLADecayState(NamedTuple):
    R: jax.Array
    P: jax.Array
    m: jax.Array
    E: jax.Array
    n: jax.Array
    rho: jax.Array


def ahla_op(a: AHLAState, b: AHLAState) -> AHLAState:
    """Undecayed concatenation, Eq. (6.2)."""
    return AHLAState(
        R=a.R + b.R,
        P=a.P + b.P,
        m=a.m + b.m,
        E=a.E + b.E + jnp.einsum("...ij,...je->...ie", b.R, a.P),
        n=a.n + b.n + jnp.einsum("...ij,...j->...i", b.R, a.m),
    )


def ahla_op_decay(a: AHLADecayState, b: AHLADecayState) -> AHLADecayState:
    """Corrected decay-aware concatenation: R~ composes undecayed."""
    rB, rBv = b.rho[..., None, None], b.rho[..., None]
    return AHLADecayState(
        R=a.R + b.R,
        P=rB * a.P + b.P,
        m=rBv * a.m + b.m,
        E=rB * a.E + b.E + rB * jnp.einsum("...ij,...je->...ie", b.R, a.P),
        n=rBv * a.n + b.n + rBv * jnp.einsum("...ij,...j->...i", b.R, a.m),
        rho=a.rho * b.rho,
    )


def ahla_op_decay_paper(a: AHLADecayState, b: AHLADecayState) -> AHLADecayState:
    """Paper's printed decayed concatenation (Section 6.2) with decayed R.

    Not associative — kept for the erratum property test only.
    """
    rB, rBv = b.rho[..., None, None], b.rho[..., None]
    return AHLADecayState(
        R=rB * a.R + b.R,
        P=rB * a.P + b.P,
        m=rBv * a.m + b.m,
        E=rB * a.E + b.E + jnp.einsum("...ij,...je->...ie", b.R, rB * a.P),
        n=rBv * a.n + b.n + jnp.einsum("...ij,...j->...i", b.R, rBv * a.m),
        rho=a.rho * b.rho,
    )


def ahla_scan(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6,
    state: Optional[AHLAState] = None,
):
    """Token-level associative scan under Eq. (6.2) (+ corrected decay)."""
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n = q.shape[-2]
    q32 = jnp.moveaxis(q.astype(dtype), -2, 0)
    k32 = jnp.moveaxis(k.astype(dtype), -2, 0)
    v32 = jnp.moveaxis(v.astype(dtype), -2, 0)

    dR = k32[..., :, None] * q32[..., None, :]
    dP = k32[..., :, None] * v32[..., None, :]
    dm = k32
    # single-token segment: E = k (q^T P_incl) = k (q^T k) v^T, n analog.
    qk = jnp.einsum("n...d,n...d->n...", q32, k32)
    dE = qk[..., None, None] * dP
    dn = qk[..., None] * k32
    g = jnp.broadcast_to(
        _gamma_arr(gamma, batch_shape, dtype)[None], (n,) + batch_shape
    )
    elems = AHLADecayState(dR, dP, dm, dE, dn, g)
    inc = jax.lax.associative_scan(ahla_op_decay, elems, axis=0)
    R, P, m, E, nn = inc.R, inc.P, inc.m, inc.E, inc.n
    if state is not None:
        rho_seg = jnp.cumprod(g, axis=0)
        a = AHLADecayState(
            state.R, state.P, state.m, state.E, state.n,
            jnp.ones(batch_shape, dtype),
        )
        merged = ahla_op_decay(a, AHLADecayState(R, P, m, E, nn, rho_seg))
        R, P, m, E, nn = merged.R, merged.P, merged.m, merged.E, merged.n
    o = jnp.einsum("n...d,n...de->n...e", q32, E)
    if normalize:
        den = jnp.einsum("n...d,n...d->n...", q32, nn)
        o = o / (den[..., None] + eps)
    out = jnp.moveaxis(o, 0, -2).astype(v.dtype)
    return out, AHLAState(R[-1], P[-1], m[-1], E[-1], nn[-1])


# -------------------- chunkwise (two linear-attention passes) --------------


def ahla_chunkwise(
    q, k, v, gamma=None, *, chunk: int = 64, normalize: bool = False,
    eps: float = 1e-6, state: Optional[AHLAState] = None,
):
    """AHLA = LinAttn(q, k, LinAttn(q, k, v)) with chunked passes.

    The (P, m) carry feeds the inner pass; (E, n) the outer.  Exactly the
    serial recurrence (Theorem 6.1), MXU-shaped.
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    d, dv = q.shape[-1], v.shape[-1]
    if state is None:
        state = ahla_init_state(batch_shape, d, dv, dtype)
    inner0 = LinAttnState(state.P.astype(dtype), state.m.astype(dtype))
    outer0 = LinAttnState(state.E.astype(dtype), state.n.astype(dtype))

    # inner pass: r_t = q_t^T P_t, s_t = q_t^T m_t (value-augmented trick to
    # share one pass: append a ones column to V)
    ones = jnp.ones(v.shape[:-1] + (1,), dtype)
    v_aug = jnp.concatenate([v.astype(dtype), ones], axis=-1)
    y, inner1 = linattn_chunkwise(q, k, v_aug, gamma, chunk=chunk, state=LinAttnState(
        P=jnp.concatenate([inner0.P, inner0.m[..., None]], -1), m=inner0.m))
    r, s = y[..., :dv], y[..., dv:]
    # outer pass on values r (and s for the denominator)
    y2, outer1 = linattn_chunkwise(
        q, k, jnp.concatenate([r, s], -1), gamma, chunk=chunk,
        state=LinAttnState(
            P=jnp.concatenate([outer0.P, outer0.m[..., None]], -1),
            m=inner0.m,
        ),
    )
    num, den = y2[..., :dv], y2[..., dv]
    o = num / (den[..., None] + eps) if normalize else num
    # final state: R must accumulate undecayed sum k q^T
    R = state.R.astype(dtype) + jnp.einsum(
        "...td,...te->...de", k.astype(dtype), q.astype(dtype)
    )
    Pf = inner1.P[..., :dv]
    mf = inner1.P[..., dv]
    Ef = outer1.P[..., :dv]
    nf = outer1.P[..., dv]
    return o.astype(v.dtype), AHLAState(R, Pf, mf, Ef, nf)


def ahla(
    q, k, v, gamma=None, *, impl: str = "chunkwise", chunk: int = 64,
    normalize: bool = False, eps: float = 1e-6,
    state: Optional[AHLAState] = None,
):
    if impl == "chunkwise":
        return ahla_chunkwise(
            q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
            state=state,
        )
    if impl == "scan":
        return ahla_scan(
            q, k, v, gamma, normalize=normalize, eps=eps, state=state
        )
    if impl == "serial":
        return ahla_serial(
            q, k, v, gamma, normalize=normalize, eps=eps, state=state
        )
    if impl == "naive":
        return ahla_naive(q, k, v, gamma, normalize=normalize, eps=eps), None
    raise ValueError(impl)
