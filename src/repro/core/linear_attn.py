"""First-order (identity feature map) linear attention — Section 2.2 baseline.

Also the inner building block of AHLA (= LinAttn o LinAttn) and of the exact
third-order operator (= HLA2 o LinAttn); see DESIGN.md §2.

    o_t = sum_{j<=t} gamma^(t-j) (q_t . k_j) v_j      (masked, decayed)

State: P = sum g^(t-j) k_j v_j^T  (d, dv),  m = sum g^(t-j) k_j  (d,).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .hla2 import _compute_dtype, _decay_matrices, _gamma_arr


class LinAttnState(NamedTuple):
    P: jax.Array  # (..., d, dv)
    m: jax.Array  # (..., d)


def linattn_init_state(batch_shape, d, dv, dtype=jnp.float32) -> LinAttnState:
    z = functools.partial(jnp.zeros, dtype=dtype)
    return LinAttnState(P=z(batch_shape + (d, dv)), m=z(batch_shape + (d,)))


def linattn_step(
    state: LinAttnState,
    q_t: jax.Array,
    k_t: jax.Array,
    v_t: jax.Array,
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
):
    dtype = state.P.dtype
    q_t, k_t, v_t = q_t.astype(dtype), k_t.astype(dtype), v_t.astype(dtype)
    g = _gamma_arr(gamma, q_t.shape[:-1], dtype)
    P = g[..., None, None] * state.P + k_t[..., :, None] * v_t[..., None, :]
    m = g[..., None] * state.m + k_t
    o = jnp.einsum("...d,...de->...e", q_t, P)
    if normalize:
        den = jnp.einsum("...d,...d->...", q_t, m)
        o = o / (den[..., None] + eps)
    return LinAttnState(P, m), o


def linattn_naive(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6
):
    dtype = _compute_dtype(q)
    q32, k32, v32 = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    n = q.shape[-2]
    g = _gamma_arr(gamma, q.shape[:-2], dtype)
    Lg, _ = _decay_matrices(n, g, dtype)
    A = jnp.einsum("...td,...jd->...tj", q32, k32) * Lg
    num = jnp.einsum("...tj,...je->...te", A, v32)
    if normalize:
        num = num / (jnp.sum(A, -1)[..., None] + eps)
    return num.astype(v.dtype)


def linattn_chunkwise(
    q,
    k,
    v,
    gamma=None,
    *,
    chunk: int = 64,
    normalize: bool = False,
    eps: float = 1e-6,
    state: Optional[LinAttnState] = None,
):
    """Chunkwise masked linear attention.  Returns (o, final_state).

    o_t = g^t q_t P0  +  row_t[(Q K^T . Lg) V]   per chunk, carry updated by
    P0' = g^w P0 + sum g^(w-j) k_j v_j^T.
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    w = min(chunk, n)
    if n % w != 0:
        pad = w - n % w
        zq = jnp.zeros(batch_shape + (pad, d), q.dtype)
        zv = jnp.zeros(batch_shape + (pad, dv), v.dtype)
        out, st = linattn_chunkwise(
            jnp.concatenate([q, zq], -2),
            jnp.concatenate([k, zq], -2),
            jnp.concatenate([v, zv], -2),
            gamma, chunk=w, normalize=normalize, eps=eps, state=state,
        )
        if gamma is not None:
            inv = 1.0 / jnp.power(_gamma_arr(gamma, batch_shape, dtype), float(pad))
            st = LinAttnState(st.P * inv[..., None, None], st.m * inv[..., None])
        return out[..., :n, :], st
    nc = n // w

    g = _gamma_arr(gamma, batch_shape, dtype)
    Lg, pow_t = _decay_matrices(w, g, dtype)
    t_idx = jnp.arange(w)
    pow_rev = jnp.power(g[..., None], (w - t_idx - 1).astype(dtype))
    rho_w = jnp.power(g, float(w))

    if state is None:
        state = linattn_init_state(batch_shape, d, dv, dtype)
    st0 = LinAttnState(*(x.astype(dtype) for x in state))

    qc = jnp.moveaxis(q.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    kc = jnp.moveaxis(k.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    vc = jnp.moveaxis(v.astype(dtype).reshape(batch_shape + (nc, w, dv)), -3, 0)

    def body(carry: LinAttnState, qkv):
        Q, K, V = qkv
        P0, m0 = carry
        A = jnp.einsum("...td,...jd->...tj", Q, K) * Lg
        num = pow_t[..., None] * jnp.einsum("...td,...de->...te", Q, P0)
        num = num + jnp.einsum("...tj,...je->...te", A, V)
        if normalize:
            den = pow_t * jnp.einsum("...td,...d->...t", Q, m0) + jnp.sum(A, -1)
            o = num / (den[..., None] + eps)
        else:
            o = num
        Kg = pow_rev[..., None] * K
        P = rho_w[..., None, None] * P0 + jnp.einsum("...td,...te->...de", Kg, V)
        m = rho_w[..., None] * m0 + jnp.einsum("...td->...d", Kg)
        return LinAttnState(P, m), o

    final, outs = jax.lax.scan(body, st0, (qc, kc, vc))
    out = jnp.moveaxis(outs, 0, -3).reshape(batch_shape + (n, dv))
    return out.astype(v.dtype), final


def linattn(
    q, k, v, gamma=None, *, impl: str = "chunkwise", chunk: int = 64,
    normalize: bool = False, eps: float = 1e-6,
    state: Optional[LinAttnState] = None,
):
    if impl == "chunkwise":
        return linattn_chunkwise(
            q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
            state=state,
        )
    if impl == "naive":
        return linattn_naive(q, k, v, gamma, normalize=normalize, eps=eps), None
    raise ValueError(impl)
