"""Associative operators used by the chunk-parallel scans (paper §4, §6, §7).

Aggregated here for the property tests (associativity, identity, scan
prefix equivalence) and for documentation.  Each operator composes the
summary of segment A followed by segment B.
"""

from .ahla import (
    AHLADecayState,
    AHLAState,
    ahla_op,
    ahla_op_decay,
    ahla_op_decay_paper,
)
from .hla2 import (
    HLA2DecayState,
    HLA2State,
    masked_op,
    masked_op_decay,
    masked_op_decay_paper,
)
from .hla3 import HLA3ScanState, hla3_op

__all__ = [
    "HLA2State",
    "HLA2DecayState",
    "masked_op",
    "masked_op_decay",
    "masked_op_decay_paper",
    "AHLAState",
    "AHLADecayState",
    "ahla_op",
    "ahla_op_decay",
    "ahla_op_decay_paper",
    "HLA3ScanState",
    "hla3_op",
]
