"""Third-order Linear Attention — paper Section 7, plus a corrected variant.

Paper-faithful operators
------------------------
* ``hla3_paper_serial``  — Algorithm 3 verbatim (state S^K, S^Q, P, m,
  G^(1..3), h^(1..3); decay as printed).
* ``hla3_paper_scan``    — Algorithm 4 / Theorem 7.2: associative scan under
  the composition (7.6)-(7.7) with the segment maps M^KQP / M^KQm
  *materialized* as dense 4-/3-tensors (O(d^3 dv) per element — the cost the
  paper quotes; test-scale d only).
* ``hla3_paper_chunkwise`` — production path: sequential inter-chunk carry
  of (S^K, S^Q, P, m, F, eta); intra-chunk outputs and the ⊗3 cross terms
  evaluated as masked matmuls via the scalar identities
  ``D^K Z D^P = (k^T Z k) k v^T`` and ``D^K D^Q = (k.q) k q^T`` — the maps
  are applied to the carry, never materialized (gamma = 1, as Alg. 4).

Erratum (2) — Theorem 7.1 (documented in DESIGN.md §7)
------------------------------------------------------
The paper claims Algorithm 3 computes ``row_t[((W W^T) ⊙ L)(W V)]`` with
``W = L ⊙ (QK^T)``.  Region analysis of the inclusion–exclusion shows
otherwise: with triples (i = inner key, u = middle query, j = value index),
the target causal region is ``{i <= u, j <= u, u <= t}`` (u is a *weak*
max), while ``S S^Q P - G1 - G2 - G3`` removes the three disjoint regions
where one index is the *strict unique* max, leaving the "no strict unique
max" region.  E.g. the causal triple (i,u,j) = (1,5,3) is wrongly
subtracted by G2.  Both operators are strictly causal and O(d^2 + d dv)
streaming; they simply differ.  We implement the paper's operator verbatim
(it is self-consistent: Alg 3 == Eq (7.5) == Alg 4, all tested) and
additionally provide the operator matching the stated target:

* ``hla3_exact``: note ``(W V)_u = r_u`` is first-order linear attention and
  ``((W W^T) ⊙ L)_{t,u} = q_t^T S_u^K q_u`` is exactly the masked HLA2
  weight, so the exact third-order operator factors as

      HLA3_exact(Q, K, V) = HLA2_masked(Q, K, values = LinAttn(Q, K, V))

  — implemented as two chunked passes, with streaming/decode state
  (LinAttnState, HLA2State) and decay applied per pass.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .hla2 import (
    HLA2State,
    _compute_dtype,
    _decay_matrices,
    _gamma_arr,
    hla2_chunkwise,
    hla2_init_state,
    hla2_naive,
    hla2_step,
)
from .linear_attn import (
    LinAttnState,
    linattn_chunkwise,
    linattn_init_state,
    linattn_naive,
    linattn_step,
)

# ===========================================================================
# Paper-faithful third order (Algorithm 3 / 4)
# ===========================================================================


class HLA3PaperState(NamedTuple):
    SK: jax.Array  # (..., d, d)
    SQ: jax.Array  # (..., d, d)
    P: jax.Array  # (..., d, dv)
    m: jax.Array  # (..., d)
    G1: jax.Array  # (..., d, dv)
    G2: jax.Array  # (..., d, dv)
    G3: jax.Array  # (..., d, dv)
    h1: jax.Array  # (..., d)
    h2: jax.Array  # (..., d)
    h3: jax.Array  # (..., d)


def hla3_paper_init_state(batch_shape, d, dv, dtype=jnp.float32):
    z = functools.partial(jnp.zeros, dtype=dtype)
    return HLA3PaperState(
        SK=z(batch_shape + (d, d)),
        SQ=z(batch_shape + (d, d)),
        P=z(batch_shape + (d, dv)),
        m=z(batch_shape + (d,)),
        G1=z(batch_shape + (d, dv)),
        G2=z(batch_shape + (d, dv)),
        G3=z(batch_shape + (d, dv)),
        h1=z(batch_shape + (d,)),
        h2=z(batch_shape + (d,)),
        h3=z(batch_shape + (d,)),
    )


def hla3_paper_step(
    state: HLA3PaperState, q_t, k_t, v_t, gamma=None,
    *, normalize: bool = False, eps: float = 1e-6,
):
    """Algorithm 3, one token, decay placed exactly as printed."""
    dtype = state.SK.dtype
    q_t, k_t, v_t = q_t.astype(dtype), k_t.astype(dtype), v_t.astype(dtype)
    g = _gamma_arr(gamma, q_t.shape[:-1], dtype)
    gv, gm = g[..., None], g[..., None, None]

    SKp, SQp, Pp, mp = state.SK, state.SQ, state.P, state.m

    SK = gm * SKp + k_t[..., :, None] * k_t[..., None, :]
    SQ = gm * SQp + q_t[..., :, None] * q_t[..., None, :]
    P = gm * Pp + k_t[..., :, None] * v_t[..., None, :]
    m = gv * mp + k_t

    u1 = jnp.einsum("...ij,...j->...i", SQp, k_t)  # S^Q_prev k_t
    G1 = gm * state.G1 + k_t[..., :, None] * jnp.einsum(
        "...d,...de->...e", u1, Pp
    )[..., None, :]
    h1 = gv * state.h1 + k_t * jnp.einsum("...d,...d->...", u1, mp)[..., None]

    a2 = jnp.einsum("...ij,...j->...i", SKp, q_t)  # S^K_prev q_t
    G2 = gm * state.G2 + a2[..., :, None] * jnp.einsum(
        "...d,...de->...e", q_t, Pp
    )[..., None, :]
    h2 = gv * state.h2 + a2 * jnp.einsum("...d,...d->...", q_t, mp)[..., None]

    u3 = jnp.einsum("...ij,...j->...i", SQp, k_t)
    a3 = jnp.einsum("...ij,...j->...i", SKp, u3)
    G3 = gm * state.G3 + a3[..., :, None] * v_t[..., None, :]
    h3 = gv * state.h3 + a3

    y = jnp.einsum("...ij,...j->...i", SK, q_t)
    z = jnp.einsum("...ij,...j->...i", SQ, y)
    termA = jnp.einsum("...d,...de->...e", z, P)
    o = (
        termA
        - jnp.einsum("...d,...de->...e", q_t, G1)
        - jnp.einsum("...d,...de->...e", q_t, G2)
        - jnp.einsum("...d,...de->...e", q_t, G3)
    )
    if normalize:
        denvec = (
            jnp.einsum("...ij,...j->...i", SK, jnp.einsum("...ij,...j->...i", SQ, m))
            - h1 - h2 - h3
        )
        den = jnp.einsum("...d,...d->...", q_t, denvec)
        o = o / (den[..., None] + eps)
    new = HLA3PaperState(SK, SQ, P, m, G1, G2, G3, h1, h2, h3)
    return new, o


def hla3_paper_serial(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6,
    state: Optional[HLA3PaperState] = None,
):
    batch_shape = q.shape[:-2]
    d, dv = q.shape[-1], v.shape[-1]
    if state is None:
        state = hla3_paper_init_state(batch_shape, d, dv, _compute_dtype(q))

    def body(st, qkv):
        st, o = hla3_paper_step(st, *qkv, gamma, normalize=normalize, eps=eps)
        return st, o

    qs, ks, vs = (jnp.moveaxis(x, -2, 0) for x in (q, k, v))
    state, os_ = jax.lax.scan(body, state, (qs, ks, vs))
    return jnp.moveaxis(os_, 0, -2).astype(v.dtype), state


def hla3_paper_naive(
    q, k, v, *, normalize: bool = False, eps: float = 1e-6
):
    """Region oracle for the paper's operator (gamma = 1).

    num_t = sum over triples (i, u, j) <= t with *no strict unique max*
    of (q_t.k_i)(q_u.k_i)(q_u.k_j) v_j   — see module docstring.
    """
    dtype = _compute_dtype(q)
    q32, k32, v32 = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    n = q.shape[-2]
    idx = jnp.arange(n)
    qk = jnp.einsum("...td,...id->...ti", q32, k32)  # (q_t . k_i)
    # triple weight tensor T[u, i, j] masked per region, contracted with
    # q_t via qk[t, i]; keep n small in tests (O(n^3) memory).
    i_, u_, j_ = idx[None, :, None], idx[:, None, None], idx[None, None, :]
    i_strict_max = (i_ > u_) & (i_ > j_)
    u_strict_max = (u_ > i_) & (u_ > j_)
    j_strict_max = (j_ > i_) & (j_ > u_)
    keep = ~(i_strict_max | u_strict_max | j_strict_max)  # (u, i, j)
    keep = keep.astype(dtype)
    quk = jnp.einsum("...ud,...id->...ui", q32, k32)  # (q_u . k_i)
    quj = jnp.einsum("...ud,...jd->...uj", q32, k32)  # (q_u . k_j)
    # core[u, i, j] = (q_u.k_i)(q_u.k_j) * keep
    core = quk[..., :, :, None] * quj[..., :, None, :] * keep
    # restrict u, i, j <= t when contracting with q_t: build per-t via mask
    # num[t] = sum_{u,i,j <= t} qk[t,i] core[u,i,j] v[j]
    le = (idx[:, None] <= idx[None, :]).astype(dtype)  # [a, t] = a<=t
    # sum over i with i<=t: weight qk[t,i]*le[i,t]
    w_ti = qk * le.T  # (t, i) masked i<=t
    tmp = jnp.einsum("...ti,...uij->...tuj", w_ti, core)
    tmp = tmp * le.T[..., None]  # mask u<=t  -> le[u,t] => le.T[t,u]
    Tmat = jnp.einsum("...tuj->...tj", tmp)
    Tmat = Tmat * le.T  # mask j<=t
    num = jnp.einsum("...tj,...je->...te", Tmat, v32)
    if normalize:
        den = jnp.sum(Tmat, -1)
        num = num / (den[..., None] + eps)
    return num.astype(v.dtype)


# ----------------------- Algorithm 4: associative scan ---------------------


class HLA3ScanState(NamedTuple):
    """Paper Eq. (7.6)-(7.7) state with materialized segment maps.

    W4[a,b,c,e] = sum_t k_a k_b k_c v_e  represents M^KQP;
    W3[a,b,c]   = sum_t k_a k_b k_c      represents M^KQm.
    """

    SK: jax.Array
    SQ: jax.Array
    P: jax.Array
    m: jax.Array
    F: jax.Array  # (..., d, dv) corrected state
    eta: jax.Array  # (..., d)
    RQP: jax.Array  # (..., d, dv)
    rQm: jax.Array  # (..., d)
    UKQ: jax.Array  # (..., d, d)
    W4: jax.Array  # (..., d, d, d, dv)
    W3: jax.Array  # (..., d, d, d)


def hla3_op(a: HLA3ScanState, b: HLA3ScanState) -> HLA3ScanState:
    """⊗3 — Eqs. (7.6)–(7.7)."""
    MB_SQ = jnp.einsum("...abce,...bc->...ae", b.W4, a.SQ)
    MBm_SQ = jnp.einsum("...abc,...bc->...a", b.W3, a.SQ)
    F = (
        a.F + b.F
        + jnp.einsum("...ij,...je->...ie", a.SK, b.RQP)
        + MB_SQ
        + jnp.einsum("...ij,...je->...ie", b.UKQ, a.P)
    )
    eta = (
        a.eta + b.eta
        + jnp.einsum("...ij,...j->...i", a.SK, b.rQm)
        + MBm_SQ
        + jnp.einsum("...ij,...j->...i", b.UKQ, a.m)
    )
    return HLA3ScanState(
        SK=a.SK + b.SK, SQ=a.SQ + b.SQ, P=a.P + b.P, m=a.m + b.m,
        F=F, eta=eta, RQP=a.RQP + b.RQP, rQm=a.rQm + b.rQm,
        UKQ=a.UKQ + b.UKQ, W4=a.W4 + b.W4, W3=a.W3 + b.W3,
    )


def hla3_paper_scan(
    q, k, v, *, normalize: bool = False, eps: float = 1e-6
):
    """Algorithm 4 via token-level associative scan (Theorem 7.2).

    Faithful including materialized M maps — O(n d^3 dv) memory; use small
    d (tests).  Chunked grouping is an associativity regrouping of the same
    monoid, so this validates the chunk-parallel claim directly.
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    q32 = jnp.moveaxis(q.astype(dtype), -2, 0)
    k32 = jnp.moveaxis(k.astype(dtype), -2, 0)
    v32 = jnp.moveaxis(v.astype(dtype), -2, 0)

    DK = k32[..., :, None] * k32[..., None, :]
    DQ = q32[..., :, None] * q32[..., None, :]
    DP = k32[..., :, None] * v32[..., None, :]
    alpha = jnp.einsum("n...d,n...d->n...", q32, k32)  # (q_t . k_t)
    # F_token = DK DQ DP = alpha^2 k v^T ; eta_token = alpha^2 k
    F0 = (alpha**2)[..., None, None] * DP
    eta0 = (alpha**2)[..., None] * k32
    RQP = alpha[..., None, None] * (q32[..., :, None] * v32[..., None, :])
    rQm = alpha[..., None] * q32
    UKQ = alpha[..., None, None] * (k32[..., :, None] * q32[..., None, :])
    W4 = jnp.einsum("n...a,n...b,n...c,n...e->n...abce", k32, k32, k32, v32)
    W3 = jnp.einsum("n...a,n...b,n...c->n...abc", k32, k32, k32)

    elems = HLA3ScanState(DK, DQ, DP, k32, F0, eta0, RQP, rQm, UKQ, W4, W3)
    inc = jax.lax.associative_scan(hla3_op, elems, axis=0)
    o = jnp.einsum("n...d,n...de->n...e", q32, inc.F)
    if normalize:
        den = jnp.einsum("n...d,n...d->n...", q32, inc.eta)
        o = o / (den[..., None] + eps)
    return jnp.moveaxis(o, 0, -2).astype(v.dtype)


# ----------------------- production chunkwise (gamma = 1) ------------------


class HLA3ChunkState(NamedTuple):
    SK: jax.Array
    SQ: jax.Array
    P: jax.Array
    m: jax.Array
    F: jax.Array
    eta: jax.Array


def hla3_chunk_init_state(batch_shape, d, dv, dtype=jnp.float32):
    """Zero carry for ``hla3_paper_chunkwise`` — the canonical streaming
    state for the paper's third-order operator.  Decode steps run the
    chunkwise path at n = 1 (``hla3_paper_chunk_step``) so prefill and
    decode share one state layout; the 10-field ``HLA3PaperState`` remains
    the Algorithm-3-verbatim form (serial/scan fidelity paths only).
    """
    z = functools.partial(jnp.zeros, dtype=dtype)
    return HLA3ChunkState(
        SK=z(batch_shape + (d, d)), SQ=z(batch_shape + (d, d)),
        P=z(batch_shape + (d, dv)), m=z(batch_shape + (d,)),
        F=z(batch_shape + (d, dv)), eta=z(batch_shape + (d,)),
    )


def hla3_paper_chunk_step(
    state: HLA3ChunkState, q_t, k_t, v_t,
    *, normalize: bool = False, eps: float = 1e-6,
):
    """One decode token in chunk-state space (n = 1 chunkwise call).

    Keeps decode bit-consistent with ``hla3_paper_chunkwise`` prefill —
    the Algorithm-3 step (``hla3_paper_step``) carries a different
    (10-field) decomposition of the same operator, so mixing the two
    layouts across prefill/decode is a tree-structure error.  gamma = 1,
    as the paper states Algorithm 4's chunk path.
    """
    o, new = hla3_paper_chunkwise(
        q_t[..., None, :], k_t[..., None, :], v_t[..., None, :],
        chunk=1, normalize=normalize, eps=eps, state=state,
    )
    return new, o[..., 0, :]


def hla3_paper_chunkwise(
    q, k, v, *, chunk: int = 64, normalize: bool = False, eps: float = 1e-6,
    state: Optional[HLA3ChunkState] = None,
):
    """Paper third-order operator, chunk-parallel, maps applied to carry.

    Intra-chunk masked-matmul expansion of the F-recurrence (7.5); the ⊗3
    cross terms (7.7) contract the carry with per-token scalars:

        alpha_u = q_u . k_u          beta_u = k_u^T S_A^Q k_u

    so M_B[S_A^Q] = K^T diag(beta) V etc. — never materializing d^3 maps.
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    w = min(chunk, n)
    if n % w != 0:
        pad = w - n % w
        zq = jnp.zeros(batch_shape + (pad, d), q.dtype)
        zv = jnp.zeros(batch_shape + (pad, dv), v.dtype)
        out, st = hla3_paper_chunkwise(
            jnp.concatenate([q, zq], -2),
            jnp.concatenate([k, zq], -2),
            jnp.concatenate([v, zv], -2),
            chunk=w, normalize=normalize, eps=eps, state=state,
        )
        return out[..., :n, :], st  # zero tokens are exact no-ops at gamma=1
    nc = n // w

    idx = jnp.arange(w)
    L = (idx[:, None] >= idx[None, :]).astype(dtype)  # incl
    Lst = (idx[:, None] > idx[None, :]).astype(dtype)  # strict
    Ust = (idx[:, None] < idx[None, :]).astype(dtype)  # strict upper

    if state is None:
        z = functools.partial(jnp.zeros, dtype=dtype)
        state = HLA3ChunkState(
            SK=z(batch_shape + (d, d)), SQ=z(batch_shape + (d, d)),
            P=z(batch_shape + (d, dv)), m=z(batch_shape + (d,)),
            F=z(batch_shape + (d, dv)), eta=z(batch_shape + (d,)),
        )
    st0 = HLA3ChunkState(*(x.astype(dtype) for x in state))

    qc = jnp.moveaxis(q.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    kc = jnp.moveaxis(k.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    vc = jnp.moveaxis(v.astype(dtype).reshape(batch_shape + (nc, w, dv)), -3, 0)

    def body(carry: HLA3ChunkState, qkv):
        Q, K, V = qkv
        SA, SQA, PA, mA, FA, etaA = carry
        ones = jnp.ones(batch_shape + (w, 1), dtype)
        Vb = jnp.concatenate([V, ones], -1)  # fuse num/den columns

        alpha = jnp.einsum("...td,...td->...t", Q, K)
        beta = jnp.einsum("...td,...de,...te->...t", K, SQA, K)
        A = jnp.einsum("...td,...jd->...tj", Q, K) * L  # (QK^T).L
        KQs = jnp.einsum("...td,...jd->...tj", K, Q) * Ust  # (KQ^T), i<u
        QKsV = jnp.einsum("...tj,...je->...te",
                          jnp.einsum("...td,...jd->...tj", Q, K) * Lst, Vb)
        # Y[u] = q_u^T P_{u-1}^loc  (strictly-lower first-order outputs)
        Y = QKsV  # (w, dv+1)

        # ---- local F terms (Eq. 7.5 expanded; see module docstring) ----
        # (a) ((A_incl . (K Q^T strict-upper composed)) ) diag(alpha) V:
        W2s = jnp.einsum("...ti,...iu->...tu", A, KQs) * L  # q_t^T S^K_{u-1} q_u
        TA = jnp.einsum("...tu,...u,...ue->...te", W2s, alpha, Vb)
        # (b) A diag(beta_loc) V with beta_loc = k_u^T S^Q_{u-1,loc} k_u
        KQl = jnp.einsum("...ud,...jd->...uj", K, Q) * Lst  # (k_u.q_j), j<u
        beta_loc = jnp.einsum("...uj,...uj->...u", KQl, KQl)
        TB = jnp.einsum("...tu,...u,...ue->...te", A, beta_loc, Vb)
        # (c) A diag(alpha) Y
        TC = jnp.einsum("...tu,...u,...ue->...te", A, alpha, Y)
        # (d) A diag(alpha^2) V
        TD = jnp.einsum("...tu,...u,...ue->...te", A, alpha**2, Vb)

        # ---- carry cross terms (⊗3 with A = carry, B = local prefix) ----
        # q_t^T F_A
        X0 = jnp.einsum("...td,...de->...te", Q,
                        jnp.concatenate([FA, etaA[..., None]], -1))
        # S_A^K R_B(t):  ((Q S_A Q^T).L) diag(alpha) V
        QSQ = jnp.einsum("...td,...de,...ue->...tu", Q, SA, Q) * L
        X1 = jnp.einsum("...tu,...u,...ue->...te", QSQ, alpha, Vb)
        # M_B(t)[S_A^Q]: A diag(beta) V
        X2 = jnp.einsum("...tu,...u,...ue->...te", A, beta, Vb)
        # U_B(t) P_A: A diag(alpha) (Q [P_A | m_A])
        QPA = jnp.einsum("...ud,...de->...ue", Q,
                         jnp.concatenate([PA, mA[..., None]], -1))
        X3 = jnp.einsum("...tu,...u,...ue->...te", A, alpha, QPA)

        allt = X0 + X1 + X2 + X3 + TA + TB + TC + TD
        num, den = allt[..., :dv], allt[..., dv]
        o = num / (den[..., None] + eps) if normalize else num

        # ---- chunk summary -> new carry (⊗3 with B = whole chunk) ----
        SB = jnp.einsum("...ti,...tj->...ij", K, K)
        SQB = jnp.einsum("...ti,...tj->...ij", Q, Q)
        PB = jnp.einsum("...td,...te->...de", K, Vb)  # last col = m_B
        RQPB = jnp.einsum("...t,...td,...te->...de", alpha, Q, Vb)
        UKQB = jnp.einsum("...t,...td,...tj->...dj", alpha, K, Q)
        MB_SQA = jnp.einsum("...t,...td,...te->...de", beta, K, Vb)
        # F_B local: sum over u of the four (a)-(d) column contributions
        Z1 = jnp.einsum("...td,...tu->...du", K, KQs)  # S^K_{u-1} q_u columns
        FB = (
            jnp.einsum("...du,...u,...ue->...de", Z1, alpha, Vb)
            + jnp.einsum("...ud,...u,...ue->...de", K, beta_loc, Vb)
            + jnp.einsum("...ud,...u,...ue->...de", K, alpha, Y)
            + jnp.einsum("...ud,...u,...ue->...de", K, alpha**2, Vb)
        )
        Fnew_aug = (
            jnp.concatenate([FA, etaA[..., None]], -1) + FB
            + jnp.einsum("...ij,...je->...ie", SA, RQPB)
            + MB_SQA
            + jnp.einsum("...ij,...je->...ie", UKQB,
                         jnp.concatenate([PA, mA[..., None]], -1))
        )
        new = HLA3ChunkState(
            SK=SA + SB, SQ=SQA + SQB, P=PA + PB[..., :dv], m=mA + PB[..., dv],
            F=Fnew_aug[..., :dv], eta=Fnew_aug[..., dv],
        )
        return new, o

    final, outs = jax.lax.scan(body, st0, (qc, kc, vc))
    out = jnp.moveaxis(outs, 0, -3).reshape(batch_shape + (n, dv))
    return out.astype(v.dtype), final


# ===========================================================================
# Exact masked third order:  HLA3_exact = HLA2_masked ∘ LinAttn
# ===========================================================================


class HLA3ExactState(NamedTuple):
    inner: LinAttnState  # (P, m) first-order pass
    outer: HLA2State  # second-order pass over values (r | s)


def hla3_exact_init_state(batch_shape, d, dv, dtype=jnp.float32):
    return HLA3ExactState(
        inner=linattn_init_state(batch_shape, d, dv + 1, dtype),
        outer=hla2_init_state(batch_shape, d, dv + 1, dtype),
    )


def hla3_exact_step(
    state: HLA3ExactState, q_t, k_t, v_t, gamma=None,
    *, normalize: bool = False, eps: float = 1e-6,
):
    dtype = state.inner.P.dtype
    v_aug = jnp.concatenate(
        [v_t.astype(dtype), jnp.ones(v_t.shape[:-1] + (1,), dtype)], -1
    )
    inner, rs = linattn_step(state.inner, q_t, k_t, v_aug, gamma)
    outer, o_aug = hla2_step(state.outer, q_t, k_t, rs, gamma)
    num, den = o_aug[..., :-1], o_aug[..., -1]
    o = num / (den[..., None] + eps) if normalize else num
    return HLA3ExactState(inner, outer), o


def hla3_exact_serial(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6,
    state: Optional[HLA3ExactState] = None,
):
    batch_shape = q.shape[:-2]
    d, dv = q.shape[-1], v.shape[-1]
    if state is None:
        state = hla3_exact_init_state(batch_shape, d, dv, _compute_dtype(q))

    def body(st, qkv):
        st, o = hla3_exact_step(st, *qkv, gamma, normalize=normalize, eps=eps)
        return st, o

    qs, ks, vs = (jnp.moveaxis(x, -2, 0) for x in (q, k, v))
    state, os_ = jax.lax.scan(body, state, (qs, ks, vs))
    return jnp.moveaxis(os_, 0, -2).astype(v.dtype), state


def hla3_exact_chunkwise(
    q, k, v, gamma=None, *, chunk: int = 64, normalize: bool = False,
    eps: float = 1e-6, state: Optional[HLA3ExactState] = None,
):
    """Exact masked third order via LinAttn pass then HLA2 pass (chunked)."""
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    d, dv = q.shape[-1], v.shape[-1]
    if state is None:
        state = hla3_exact_init_state(batch_shape, d, dv, dtype)
    ones = jnp.ones(v.shape[:-1] + (1,), dtype)
    v_aug = jnp.concatenate([v.astype(dtype), ones], -1)
    rs, inner = linattn_chunkwise(
        q, k, v_aug, gamma, chunk=chunk, state=state.inner
    )
    o_aug, outer = hla2_chunkwise(
        q, k, rs, gamma, chunk=chunk, state=state.outer
    )
    num, den = o_aug[..., :-1], o_aug[..., -1]
    o = num / (den[..., None] + eps) if normalize else num
    return o.astype(v.dtype), HLA3ExactState(inner, outer)


def hla3_exact_naive(
    q, k, v, gamma=None, *, normalize: bool = False, eps: float = 1e-6
):
    """Independent oracle: o = ((W W^T) ⊙ L)(W V), decayed per pass."""
    dtype = _compute_dtype(q)
    ones = jnp.ones(v.shape[:-1] + (1,), dtype)
    v_aug = jnp.concatenate([v.astype(dtype), ones], -1)
    rs = linattn_naive(q, k, v_aug, gamma)
    o_aug = hla2_naive(q, k, rs, gamma)
    num, den = o_aug[..., :-1], o_aug[..., -1]
    return (num / (den[..., None] + eps) if normalize else num).astype(v.dtype)


def hla3(
    q, k, v, gamma=None, *, impl: str = "chunkwise", form: str = "exact",
    chunk: int = 64, normalize: bool = False, eps: float = 1e-6, state=None,
):
    """Front-end.  form: 'exact' (corrected) or 'paper' (Alg 3/4)."""
    if form == "exact":
        if impl == "chunkwise":
            return hla3_exact_chunkwise(
                q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
                state=state,
            )
        if impl == "serial":
            return hla3_exact_serial(
                q, k, v, gamma, normalize=normalize, eps=eps, state=state
            )
        if impl == "naive":
            return hla3_exact_naive(
                q, k, v, gamma, normalize=normalize, eps=eps
            ), None
    else:
        if impl == "chunkwise":
            if gamma is not None:
                raise NotImplementedError(
                    "paper Alg. 4 chunk path is stated for gamma = 1"
                )
            return hla3_paper_chunkwise(
                q, k, v, chunk=chunk, normalize=normalize, eps=eps, state=state
            )
        if impl == "scan":
            return hla3_paper_scan(q, k, v, normalize=normalize, eps=eps), None
        if impl == "serial":
            return hla3_paper_serial(
                q, k, v, gamma, normalize=normalize, eps=eps, state=state
            )
        if impl == "naive":
            return hla3_paper_naive(q, k, v, normalize=normalize, eps=eps), None
    raise ValueError((impl, form))
