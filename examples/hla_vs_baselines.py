"""Expressivity comparison on associative recall: HLA2 / AHLA / HLA3 vs
first-order linear attention vs softmax attention.

The paper positions HLA's data-dependent metric S^K as strictly richer
than first-order linearizations (§3 'Connection with linear attention').
Associative recall (k1 v1 k2 v2 ... query-k -> v) is the standard probe.

    PYTHONPATH=src python examples/hla_vs_baselines.py [--steps 400]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.distributed import steps as steps_mod
from repro.models import lm
from repro.models.param import init_params
from repro.optim import adamw


def accuracy(params, cfg, stream, steps=5):
    hits = tot = 0
    for s in range(1000, 1000 + steps):
        b = stream.batch(s)
        logits, _, _ = lm.lm_apply(
            params, jnp.asarray(b["tokens"]), cfg, mode="train"
        )
        pred = np.asarray(jnp.argmax(logits, -1))
        lbl = b["labels"]
        mask = lbl >= 0
        hits += (pred[mask] == lbl[mask]).sum()
        tot += mask.sum()
    return hits / max(tot, 1)


def run(mixer, args):
    cfg = get_config("hla-1b", reduced=True).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=64,
    )
    if mixer != "hla2":
        cfg = cfg.replace(mixer=mixer)
    stream = SyntheticStream(
        DataConfig(cfg.vocab, args.seq, args.batch, seed=0, kind="recall")
    )
    params = init_params(steps_mod.model_specs(cfg), jax.random.key(0))
    opt_cfg = adamw.OptConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps, weight_decay=0.01)
    opt = adamw.init_opt_state(params)
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
    for s in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
        params, opt, m = step(params, opt, b)
    acc = accuracy(params, cfg, stream)
    print(f"{mixer:10s} recall accuracy: {acc*100:5.1f}%  "
          f"(final loss {float(m['loss']):.3f})")
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=800)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=18)
    args = ap.parse_args()
    for mixer in ("softmax", "linattn", "hla2", "ahla", "hla3"):
        run(mixer, args)


if __name__ == "__main__":
    main()
