"""Long-context decode with O(1) state — the paper's headline property.

Streams a long context token-by-token through the HLA2 recurrence
(Fig. 1(A)); the state size is CONSTANT however long the context gets,
vs a KV cache growing linearly.  Prints state-vs-cache bytes and decode
throughput at several context lengths.

    PYTHONPATH=src python examples/long_context_decode.py [--ctx 4096]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params
from repro.serving.sampling import SamplingConfig, sample


def state_bytes(tree):
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree)
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--sampling", default="greedy",
                    choices=["greedy", "temperature", "top_k"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    scfg = SamplingConfig(
        method=args.sampling, temperature=args.temperature, top_k=args.top_k
    )

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    B = args.batch

    states = lm.lm_init_states(cfg, B, args.ctx)
    sb = state_bytes(states)
    kv_cfg = cfg.replace(mixer="softmax")
    kv = jax.eval_shape(lambda: lm.lm_init_states(kv_cfg, B, args.ctx))
    print(f"HLA2 state:  {sb/2**20:8.2f} MiB  (constant in context)")
    print(f"KV cache @ {args.ctx}: "
          f"{state_bytes(kv)/2**20:8.2f} MiB  (linear in context)")

    @jax.jit
    def step(params, tok, states, pos, key):
        logits, st, _ = lm.lm_apply(
            params, tok, cfg, states=states, positions=pos, mode="decode"
        )
        key, sub = jax.random.split(key)
        nxt = sample(logits[:, -1], sub, scfg)  # shared serving sampler
        return nxt[:, None], st, key

    tok = jnp.ones((B, 1), jnp.int32)
    key = jax.random.key(args.seed)
    rng = np.random.RandomState(args.seed)
    checkpoints = [args.ctx // 4, args.ctx // 2, args.ctx]
    t0 = time.time()
    for t in range(args.ctx):
        if t % 64 == 0:  # inject fresh context tokens periodically
            tok = jnp.asarray(rng.randint(2, cfg.vocab, (B, 1)), jnp.int32)
        tok, states, key = step(params, tok, states, jnp.full((B, 1), t), key)
        if (t + 1) in checkpoints:
            dt = time.time() - t0
            print(f"ctx {t+1:7d}: {(t+1)*B/dt:8.1f} tok/s, "
                  f"state still {state_bytes(states)/2**20:.2f} MiB")
    print("decode state never grew — O(1) memory per token (paper §1).")


if __name__ == "__main__":
    main()
