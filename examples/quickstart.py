"""Quickstart: train a small HLA2 language model for a few hundred steps.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Uses the public API end to end: config -> specs -> init -> jitted train
step -> loss curve.  Runs in minutes on CPU; loss should drop well below
the uniform baseline ln(vocab).
"""

import argparse
import functools
import math

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.distributed import steps as steps_mod
from repro.models.param import init_params, param_count
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("hla-1b", reduced=True).replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512,
    )
    specs = steps_mod.model_specs(cfg)
    print(f"model: {cfg.name} ({param_count(specs):,} params, mixer={cfg.mixer})")
    params = init_params(specs, jax.random.key(0))
    opt_cfg = adamw.OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw.init_opt_state(params)
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))

    stream = SyntheticStream(
        DataConfig(cfg.vocab, args.seq, args.batch, seed=0, kind="zipf")
    )
    first = None
    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if s == 0:
            first = float(m["loss"])
        if s % 25 == 0:
            print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")
    last = float(m["loss"])
    print(f"\nuniform baseline ln({cfg.vocab}) = {math.log(cfg.vocab):.3f}")
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first * 0.7 else 'WARN: check setup'})")


if __name__ == "__main__":
    main()
