"""End-to-end driver: train a ~100M-parameter HLA2 LM for a few hundred
steps with the full production stack (mesh, sharded params, FT loop,
checkpoints, metrics jsonl).

    PYTHONPATH=src HOST_DEVICES=4 python examples/train_hla_100m.py \
        --steps 200

This is the deliverable-(b) end-to-end driver; on TPU hardware the same
script runs unchanged (drop HOST_DEVICES), with the Pallas fused kernel
active in the mixer.
"""

import os
import sys

sys.argv = [sys.argv[0]] + [
    "--arch", "hla-1b", "--reduced", "--steps",
    os.environ.get("STEPS", "200"),
    "--batch", "8", "--seq", "512", "--ckpt-dir", "/tmp/hla100m_ckpt",
    "--ckpt-every", "100", "--metrics", "/tmp/hla100m_metrics.jsonl",
] + sys.argv[1:]

# ~100M config: widen the reduced config before launch.train parses args
import repro.configs.hla_1b as hla_1b  # noqa: E402

_orig_reduced = hla_1b.reduced


def _reduced_100m():
    return hla_1b.CONFIG.replace(
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
        vocab=32768, remat="none", dtype="float32",
    )


hla_1b.reduced = _reduced_100m

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
